"""Distributed ProbGraph mining on the production mesh (shard_map).

This is the paper's own workload at pod scale. Distribution plan:

  * sketch construction: vertices sharded over ('data',) — each shard hashes
    its own CSR rows (embarrassingly parallel, paper Table V), then the
    sketch matrix is all-gathered (it is s·|CSR| bytes ≈ small by design —
    the whole point of the representation).
  * mining (TC / clustering scores): edges sharded over ('data', 'model') —
    every shard runs fixed-size AND+popcount over its edge slice and the
    partial sums `psum` into the global count. Fixed-size sketches mean the
    shards do identical work: no load imbalance, no stragglers from degree
    skew (paper Fig. 1 panel 5 — this is the property that makes the method
    SPMD-native).

`--devices N` forces N host devices (set before jax import) so the same
script demonstrates multi-device runs on CPU.
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time
from typing import Optional

# --devices must take effect before jax init
if __name__ == "__main__" and "--devices" in sys.argv:
    n = sys.argv[sys.argv.index("--devices") + 1]
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import graph as G
from repro.core import sketches as SK
from repro.core import estimators as E
from repro import engine as ENG
from repro.obs import metrics, trace


def build_sketches_distributed(graph: G.Graph, mesh: Mesh, words: int,
                               num_hashes: int, seed: int = 0) -> jax.Array:
    """Vertex-sharded Bloom construction: shard_map over the 'data' axis."""
    n = graph.n
    total = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    pad = (-n) % total
    adj = jnp.pad(graph.adj, ((0, pad), (0, 0)), constant_values=n)
    axes = P(mesh.axis_names)  # vertices over every mesh axis

    @functools.partial(shard_map, mesh=mesh, in_specs=(axes,),
                       out_specs=axes)
    def build(adj_shard):
        total_bits = words * 32
        pos, valid = SK._positions(adj_shard, n, num_hashes, total_bits, seed)
        rows = adj_shard.shape[0]
        row_idx = jnp.broadcast_to(jnp.arange(rows)[:, None, None], pos.shape)
        bits = jnp.zeros((rows, total_bits), dtype=jnp.bool_)
        bits = bits.at[row_idx.reshape(-1),
                       jnp.where(jnp.broadcast_to(valid[..., None], pos.shape),
                                 pos, 0).reshape(-1)].max(
            jnp.broadcast_to(valid[..., None], pos.shape).reshape(-1))
        return SK.pack_bits(bits)

    return build(adj)[:n]


def triangle_count_distributed(graph: G.Graph, bloom: jax.Array, mesh: Mesh,
                               num_hashes: int) -> jax.Array:
    """Edge-sharded TC_AND: psum of per-shard estimator sums / 3."""
    m = graph.m
    total = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    pad = (-m) % total
    edges = jnp.concatenate(
        [graph.edges, jnp.zeros((pad, 2), graph.edges.dtype)], axis=0)
    mask = jnp.concatenate([jnp.ones(m, bool), jnp.zeros(pad, bool)])
    total_bits = bloom.shape[1] * 32
    eaxes = P(mesh.axis_names)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(eaxes, P(None, None), eaxes),
        out_specs=P())
    def tc_shard(edge_shard, bloom_rep, mask_shard):
        ru = jnp.take(bloom_rep, edge_shard[:, 0], axis=0)
        rv = jnp.take(bloom_rep, edge_shard[:, 1], axis=0)
        ones = jnp.sum(jax.lax.population_count(ru & rv), axis=-1)
        est = E.bf_intersection_and_from_ones(ones, total_bits, num_hashes)
        local = jnp.sum(jnp.where(mask_shard, est, 0.0))
        for ax in mesh.axis_names:
            local = jax.lax.psum(local, ax)
        return local

    return tc_shard(edges, bloom, mask) / 3.0


def mine(graph: G.Graph, mesh: Optional[Mesh] = None, storage_budget: float = 0.25,
         num_hashes: int = 2, seed: int = 0):
    """End-to-end distributed TC estimate; falls back to single-device mesh."""
    if mesh is None:
        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev,), ("data",))
    words = SK.bloom_words_for_budget(graph.n, graph.m, storage_budget)
    t0 = time.perf_counter()
    bloom = build_sketches_distributed(graph, mesh, words, num_hashes, seed)
    bloom.block_until_ready()
    t_build = time.perf_counter() - t0
    t0 = time.perf_counter()
    tc = triangle_count_distributed(graph, bloom, mesh, num_hashes)
    tc = float(tc)
    t_mine = time.perf_counter() - t0
    return {"tc_estimate": tc, "build_s": t_build, "mine_s": t_mine,
            "words": words, "devices": int(np.prod(list(mesh.shape.values())))}


def mine_session(graph: G.Graph, algos: list[str], storage_budget: float = 0.25,
                 num_hashes: int = 2, seed: int = 0, use_kernel: bool = False):
    """Multi-query mining over ONE shared sketch build (engine.session).

    TC, LCC and clustering additionally share a single per-edge cardinality
    pass; 4-clique and local clustering reuse the same sketch. Returns
    {algo: (value, seconds)}.
    """
    t0 = time.perf_counter()
    sess = ENG.session(graph, "bf", storage_budget=storage_budget,
                       num_hashes=num_hashes, seed=seed, use_kernel=use_kernel)
    jax.block_until_ready(sess.sketch.data)
    results = {"build": (sess.stats()["sketch_bytes"], time.perf_counter() - t0)}

    def run_localcluster():
        # deterministic 8-seed batch; report the mean best conductance of
        # the seeds whose sweep found a valid (finite-φ) prefix
        rng = np.random.default_rng(seed + 7)
        seeds = rng.integers(0, graph.n, size=8).astype(np.int32)
        res = sess.local_cluster(seeds, alpha=0.15, eps=1e-4)
        phi = np.asarray(res.best_conductance)
        phi = phi[np.isfinite(phi)]
        return float(phi.mean()) if phi.size else float("nan")

    runners = {
        "tc": lambda: float(sess.triangle_count()),
        "lcc": lambda: float(jnp.mean(sess.local_clustering())),
        "4clique": lambda: float(sess.four_clique_count()),
        "cliques5": lambda: float(sess.five_clique_count()),
        "jp": lambda: int(sess.jarvis_patrick("jaccard", 0.05)[1]),
        "localcluster": run_localcluster,
    }
    for name in algos:
        if name not in runners:
            raise SystemExit(f"unknown algo {name!r}; pick from {sorted(runners)}")
        t0 = time.perf_counter()
        results[name] = (runners[name](), time.perf_counter() - t0)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--scale", type=int, default=12, help="Kronecker scale")
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--budget", type=float, default=0.25)
    ap.add_argument("--exact", action="store_true", help="also run exact TC")
    ap.add_argument("--algos", type=str, default="",
                    help="comma list (tc,lcc,4clique,cliques5,jp,"
                         "localcluster): run a "
                         "multi-query engine session over one shared sketch "
                         "build")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route BF popcounts through the Pallas block-gather "
                         "kernels (TPU; interpret elsewhere)")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="record spans and write a Chrome-trace/Perfetto "
                         "JSON of the run to this path")
    ap.add_argument("--metrics", action="store_true",
                    help="print a metric-registry snapshot JSON line")
    args = ap.parse_args()

    if args.trace:
        trace.enable()
        trace.clear()
    g = G.kronecker(args.scale, args.edge_factor, seed=1)
    print(f"graph: n={g.n} m={g.m} d_max={g.d_max}")

    if args.algos:
        res = mine_session(g, args.algos.split(","), storage_budget=args.budget,
                           use_kernel=args.use_kernel)
        sketch_bytes, build_s = res.pop("build")
        print(f"session: sketch={sketch_bytes/1e6:.2f}MB build={build_s:.2f}s")
        for name, (val, secs) in res.items():
            print(f"  {name:8s} = {val:<12.4g} ({secs:.2f}s)")
        # machine-readable twin of the human output (one JSON line)
        print(json.dumps({
            "event": "mine_session", "n": g.n, "m": g.m, "d_max": g.d_max,
            "budget": args.budget, "use_kernel": args.use_kernel,
            "sketch_bytes": sketch_bytes, "build_s": build_s,
            "algos": {name: {"value": val, "seconds": secs}
                      for name, (val, secs) in res.items()},
        }))
        _emit_obs(args)
        return

    ndev = len(jax.devices())
    mesh = jax.make_mesh((ndev,), ("data",))
    out = mine(g, mesh, storage_budget=args.budget)
    print(f"TC_AND={out['tc_estimate']:.0f}  build={out['build_s']:.2f}s "
          f"mine={out['mine_s']:.2f}s devices={out['devices']}")
    if args.exact:
        from repro.core import exact as X
        t0 = time.perf_counter()
        tc = int(X.exact_triangle_count(g))
        print(f"TC_exact={tc} ({time.perf_counter()-t0:.2f}s) "
              f"rel_err={abs(out['tc_estimate']-tc)/max(tc,1):.3f}")
    _emit_obs(args)


def _emit_obs(args):
    """Shared --trace/--metrics epilogue for both run modes."""
    if args.metrics:
        print(json.dumps({"event": "metrics",
                          "global": metrics.REGISTRY.snapshot()}))
    if args.trace:
        trace.export(args.trace)
        trace.disable()
        print(f"trace -> {args.trace}")


if __name__ == "__main__":
    main()
