"""Production mesh definitions.

A v5e pod is a 16×16 torus (256 chips). Single-pod runs use a
("data", "model") = (16, 16) mesh; multi-pod adds a leading "pod" axis over
the DCN links. Functions (not module constants) so importing never touches
jax device state — the dry-run driver must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """A small mesh over however many (host) devices exist — for tests."""
    return jax.make_mesh((data, model), ("data", "model"))
