"""Batched serving driver: prefill-free cached decode with request batching.

Serves a (reduced, CPU-runnable) model: requests arrive as prompts, are
teacher-forced through `decode_step` to fill the KV cache (synchronized
batch), then sampled autoregressively. On a pod the same loop runs the full
configs with the decode-cell shardings proven by the dry-run.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as CFG
from repro.models import reduced, init_params, init_cache, decode_step


@dataclasses.dataclass
class ServeConfig:
    arch: str = "gemma_2b"
    batch: int = 4
    max_len: int = 128
    temperature: float = 0.8
    seed: int = 0
    d_model: int = 128
    layers: int = 4
    vocab_size: int = 512


class BatchedServer:
    def __init__(self, sc: ServeConfig):
        self.sc = sc
        cfg = reduced(CFG.get(sc.arch), layers=sc.layers, d_model=sc.d_model,
                      heads=max(4, sc.d_model // 32), ff=sc.d_model * 4,
                      vocab=sc.vocab_size)
        self.cfg = dataclasses.replace(cfg, dtype="float32")
        self.params = init_params(self.cfg, jax.random.PRNGKey(sc.seed))
        self._step = jax.jit(
            lambda p, c, t: decode_step(p, c, self.cfg, t))

    def generate(self, prompts: List[List[int]], num_tokens: int,
                 greedy: bool = False) -> np.ndarray:
        sc, cfg = self.sc, self.cfg
        b = len(prompts)
        assert b <= sc.batch
        max_prompt = max(len(p) for p in prompts)
        cache = init_cache(cfg, b, sc.max_len)
        key = jax.random.PRNGKey(sc.seed + 1)
        # synchronized prefill via repeated decode steps (right-aligned pads)
        toks = np.zeros((b, max_prompt), np.int32)
        for i, p in enumerate(prompts):
            toks[i, max_prompt - len(p):] = p
        logits = None
        for t in range(max_prompt):
            logits, cache = self._step(self.params, cache,
                                       jnp.asarray(toks[:, t:t + 1]))
        out = np.zeros((b, num_tokens), np.int32)
        cur = None
        for t in range(num_tokens):
            lg = logits[:, 0, :cfg.vocab_size]
            if greedy:
                cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                cur = jax.random.categorical(sub, lg / sc.temperature).astype(jnp.int32)
            out[:, t] = np.asarray(cur)
            logits, cache = self._step(self.params, cache, cur[:, None])
        return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma_2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()
    sc = ServeConfig(arch=args.arch, batch=args.batch)
    server = BatchedServer(sc)
    prompts = [[1, 2, 3], [4, 5], [6], [7, 8, 9, 10]][: args.batch]
    t0 = time.perf_counter()
    out = server.generate(prompts, args.tokens)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print(out[:, :16])


if __name__ == "__main__":
    main()
