"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

``input_specs(cfg, shape)`` returns the train-batch (or decode-step) abstract
inputs — weak-type-correct, shardable, zero allocation. Modality frontends
(EnCodec frames / ViT patches) are STUBS: embeddings-mode archs get
precomputed [B, S, d_model] activations per the assignment brief.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, ShapeConfig


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        if cfg.input_mode == "tokens":
            inputs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        else:
            inputs = jax.ShapeDtypeStruct((b, 1, cfg.d_model), jnp.dtype(cfg.dtype))
        return {"inputs": inputs}
    if cfg.input_mode == "tokens":
        inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    batch: Dict[str, Any] = {
        "inputs": inputs,
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.rope_kind == "mrope":
        batch["positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
    return batch
