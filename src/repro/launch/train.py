"""Fault-tolerant training driver.

Wires together: config -> data pipeline -> sharded train step (GSPMD) ->
checkpoint/restore -> recovery loop -> straggler monitor. On this CPU
container it drives reduced configs end-to-end (examples/train_small.py);
on a real pod the same driver runs the full configs — the only difference
is the mesh and the config source.

Multi-pod notes (1000+ nodes):
  * each restart re-resolves the device set, so a shrunk pod count after a
    hardware failure restores the latest checkpoint with the *new* mesh
    (elastic resharding path in checkpoint.store).
  * gradient compression (optim.compress) applies to the cross-pod ("pod"
    axis) reduction where DCN bandwidth, not ICI, is the bottleneck.
  * stragglers: StepMonitor flags slow steps; the deployment actuator
    (re-dispatching a slice) is infra-specific and stubbed here.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro import configs as CFG
from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.data import SyntheticLMData
from repro.distributed import sharding as SH
from repro.distributed.fault import FaultInjector, StepMonitor, run_with_recovery
from repro.distributed.step import (init_train_state, make_train_step,
                                    train_state_shapes, train_state_shardings)
from repro.models import reduced
from repro.optim import AdamW, Adafactor, cosine_warmup

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class TrainRunConfig:
    arch: str = "qwen3_8b"
    use_reduced: bool = True
    steps: int = 50
    global_batch: int = 8
    seq_len: int = 64
    vocab_size: Optional[int] = 512      # reduced-vocab override (None = arch)
    lr: float = 3e-3
    warmup: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 10
    keep: int = 3
    compress_grads: bool = False
    optimizer: str = "adamw"
    mesh_shape: tuple = (1, 1)           # (data, model) over host devices
    seed: int = 0
    d_model: int = 128
    layers: int = 4


def build(run: TrainRunConfig):
    cfg = CFG.get(run.arch)
    if run.use_reduced:
        cfg = reduced(cfg, layers=run.layers, d_model=run.d_model,
                      heads=max(4, run.d_model // 32), ff=run.d_model * 4)
        cfg = dataclasses.replace(cfg, dtype="float32")
        if run.vocab_size:
            cfg = dataclasses.replace(cfg, vocab_size=run.vocab_size)
    sched = cosine_warmup(run.lr, run.warmup, run.steps)
    if run.optimizer == "adafactor":
        opt = Adafactor(learning_rate=sched)
    else:
        opt = AdamW(learning_rate=sched, keep_master=False)
    data = SyntheticLMData(vocab_size=cfg.vocab_size, seq_len=run.seq_len,
                           seed=run.seed)
    return cfg, opt, data


def train(run: TrainRunConfig, fault: Optional[FaultInjector] = None,
          on_metrics: Optional[Callable[[int, Dict[str, Any]], None]] = None):
    """Returns (final_state, history). Fault-tolerant when ckpt_dir is set."""
    cfg, opt, data = build(run)
    dsz, msz = run.mesh_shape
    mesh = (jax.make_mesh(run.mesh_shape, ("data", "model"))
            if dsz * msz > 1 else None)

    step_fn = make_train_step(cfg, opt, compress_grads=run.compress_grads)
    if mesh is not None:
        shardings = train_state_shardings(cfg, opt, mesh,
                                          compress_grads=run.compress_grads)
        step_fn = jax.jit(step_fn, in_shardings=(shardings, None),
                          out_shardings=(shardings, None), donate_argnums=0)
    else:
        step_fn = jax.jit(step_fn, donate_argnums=0)

    ckpt = AsyncCheckpointer(run.ckpt_dir, keep=run.keep) if run.ckpt_dir else None
    monitor = StepMonitor()
    history: list = []

    def fresh_state():
        with SH.use_rules(mesh):
            return init_train_state(cfg, opt, jax.random.PRNGKey(run.seed),
                                    compress_grads=run.compress_grads)

    state_box = {"state": None}

    def restore_point() -> int:
        if ckpt is None or latest_step(run.ckpt_dir) is None:
            state_box["state"] = fresh_state()
            return 0
        step = latest_step(run.ckpt_dir)
        target = train_state_shapes(cfg, opt, run.compress_grads)
        state_box["state"] = restore_checkpoint(run.ckpt_dir, step, target)
        log.info("restored checkpoint at step %d", step)
        return step

    def loop(start: int) -> int:
        state = state_box["state"]
        with SH.use_rules(mesh):
            for step in range(start, run.steps):
                if fault is not None:
                    fault.maybe_fail(step)
                t0 = time.perf_counter()
                batch = jax.tree.map(jax.numpy.asarray, data.batch(step, run.global_batch))
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                monitor.record(step, time.perf_counter() - t0)
                history.append({"step": step, "loss": loss})
                if on_metrics:
                    on_metrics(step, metrics)
                if ckpt is not None and (step + 1) % run.ckpt_every == 0:
                    ckpt.save(step + 1, state)
                state_box["state"] = state
        if ckpt is not None:
            ckpt.wait()
            ckpt.save(run.steps, state_box["state"])
            ckpt.wait()
        return run.steps

    if ckpt is not None:
        run_with_recovery(loop, restore_step=restore_point, max_restarts=5)
    else:
        restore_point()
        loop(0)
    return state_box["state"], history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--full", action="store_true",
                    help="use the full (pod-scale) config — not for CPU")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    run = TrainRunConfig(arch=args.arch, use_reduced=not args.full,
                         steps=args.steps, global_batch=args.global_batch,
                         seq_len=args.seq_len, lr=args.lr,
                         ckpt_dir=args.ckpt_dir, d_model=args.d_model,
                         layers=args.layers,
                         compress_grads=args.compress_grads,
                         optimizer=args.optimizer)
    _, history = train(run)
    print(f"first loss {history[0]['loss']:.4f} -> final {history[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
